"""Host-side asynchronous seed/feature staging.

PR 2's double-buffered prefetch overlapped the *device* half of minibatch
preparation (sampling + feature ``all_to_all``) with model compute, but
every step still blocked on host work: ``SeedStream.seeds(k)`` runs the
hash-rank argsort over all labeled nodes on the host, and its result is
synchronously transferred to the device before the prepare can even be
dispatched.  SALIENT ("Accelerating Training and Inference of GNNs with
Fast Sampling and Pipelining", arXiv 2110.08450) shows this host-side
batch-preparation pipeline is worth a large factor on top of device-side
overlap — the host must ride *ahead* of the device, not in lockstep.

``SeedStager`` is that host-side pipeline stage: a background worker
thread computes ``SeedStream.seeds(k)`` / ``salt(k)`` for future step
indices off the critical path and eagerly starts their H2D transfers via
``jax.device_put``, keeping a bounded ring of ``depth + lead`` staged
slots warm.  Drivers then consume already-resident device arrays:

  * ``depth``  — how many prepared batches the prefetch driver keeps in
                 flight (``PrefetchSpec.depth``); the stager must cover
                 them so a refill never blocks on the host.
  * ``lead``   — extra slots staged beyond the driver's own lookahead
                 (``PrefetchSpec.lead``); this is the actual host-side
                 overlap margin.

Determinism: the stager changes *when* seeds are computed, never *what*
they are — every slot is ``(stream.seeds(k), stream.salt(k))`` for a
concrete step index ``k``, and ``SeedStream`` is a pure function of
``k``.  Staged execution is therefore bit-identical to unstaged execution
for any placement scheme, executor, and prefetch depth
(``tests/test_staging.py`` asserts it).

Consumption is index-checked: ``get(k)`` serves the ring head only when
the head *is* step ``k``; any out-of-sequence request (a driver restart,
an explicit ``step_idx`` jump) drains the ring and refills it from ``k``
— exactly mirroring the prefetch drivers' queue-refill semantics, so
restarts replay the continuous run bit-for-bit.
"""
from __future__ import annotations

import collections
import threading

import jax
import numpy as np


class SeedStager:
    """Background staging of per-step seeds/salt with eager H2D transfer.

    Parameters
    ----------
    stream : repro.pipeline.prefetch.SeedStream
        The deterministic seed stream; the stager calls its pure host
        half (``seeds_host`` / ``salt_int``) off-thread — no JAX tracing
        state is touched on the worker thread beyond ``device_put``.
    depth : int, default 0
        The consuming driver's prefetch depth (``0`` for the sync
        driver).  Sizes the ring so queue refills are fully covered.
    lead : int, default 1
        Extra staged slots beyond ``depth`` — how far the host runs ahead
        of the device.  Must be >= 1 (a zero-slot ring stages nothing).
    sharding : jax.sharding.Sharding, optional
        Placement for the staged ``(P, batch)`` seed arrays (e.g. the
        shard_map executor's worker-axis ``NamedSharding``).  ``None``
        commits to the default device.

    Examples
    --------
    >>> stager = SeedStager(stream, depth=1, lead=2)     # doctest: +SKIP
    >>> seeds, salt = stager.get(0)                      # doctest: +SKIP
    >>> stager.close()                                   # doctest: +SKIP
    """

    def __init__(self, stream, *, depth: int = 0, lead: int = 1,
                 sharding=None):
        if depth < 0:
            raise ValueError(f"depth must be >= 0, got {depth}")
        if lead < 1:
            raise ValueError(
                f"staging lead must be >= 1 (got {lead}); lead 0 would "
                f"stage nothing ahead of the driver's own lookahead")
        self.stream = stream
        self.slots = int(depth) + int(lead)
        self.sharding = sharding
        self._cv = threading.Condition()
        self._ring: collections.deque = collections.deque()
        self._want: int | None = None     # next index the worker produces
        self._gen = 0                     # bumped on drain/refill (seek)
        self._error: BaseException | None = None
        self._closed = False
        self._thread = threading.Thread(target=self._worker, daemon=True,
                                        name="repro-seed-stager")
        self._thread.start()

    # ------------------------------------------------------------ producer

    def _produce(self, k: int):
        """Compute step ``k``'s seeds/salt on the host and start their
        device transfer.  Runs on the worker thread; the host half is
        pure numpy (``SeedStream.seeds_host``), then ``jax.device_put``
        enqueues the (async where supported) H2D copy.

        Under the multi-process executor the sharding spans devices this
        process cannot address; ``jax.make_array_from_callback`` then
        assembles the global array from this rank's addressable rows
        (every rank computes the identical full ``(P, batch)`` host
        table, so the rows are consistent by construction)."""
        seeds_np = self.stream.seeds_host(k)
        salt_np = np.uint32(self.stream.salt_int(k))
        if self.sharding is not None \
                and not self.sharding.is_fully_addressable:
            seeds = jax.make_array_from_callback(
                seeds_np.shape, self.sharding,
                lambda idx: seeds_np[idx])
        else:
            seeds = jax.device_put(seeds_np, self.sharding)
        salt = jax.device_put(salt_np)
        return seeds, salt

    def _worker(self) -> None:
        while True:
            with self._cv:
                while not self._closed and (
                        self._want is None
                        or len(self._ring) >= self.slots
                        or self._error is not None):
                    self._cv.wait()
                if self._closed:
                    return
                gen, k = self._gen, self._want
            try:
                item = self._produce(k)
            except BaseException as e:  # surfaced by the next get()
                with self._cv:
                    if self._gen == gen:
                        self._error = e
                        self._cv.notify_all()
                continue
            with self._cv:
                if self._gen != gen or self._closed:
                    continue            # stale: a seek raced the produce
                self._ring.append((k, item))
                self._want = k + 1
                self._cv.notify_all()

    # ------------------------------------------------------------ consumer

    def _seek_locked(self, k: int) -> None:
        self._gen += 1
        self._ring.clear()
        self._error = None
        self._want = int(k)
        self._cv.notify_all()

    def seek(self, k: int) -> None:
        """Drain the ring and restart staging from step ``k`` (also what
        an out-of-sequence ``get`` does implicitly)."""
        with self._cv:
            self._seek_locked(k)

    def get(self, k: int):
        """Staged ``(seeds, salt)`` device arrays for step ``k``.

        Serves the ring head when it is step ``k``; otherwise drains and
        refills from ``k`` (restart semantics).  Blocks until the slot is
        staged; re-raises any error the worker thread hit.
        """
        k = int(k)
        with self._cv:
            if self._closed:
                raise RuntimeError("SeedStager is closed")
            head = self._ring[0][0] if self._ring else self._want
            if head != k:
                self._seek_locked(k)
            while not self._ring:
                if self._error is not None:
                    err, self._error = self._error, None
                    self._cv.notify_all()   # let the worker retry
                    raise err
                if self._closed:
                    raise RuntimeError("SeedStager is closed")
                self._cv.wait()
            _, item = self._ring.popleft()
            self._cv.notify_all()           # a slot freed: keep staging
            return item

    # ----------------------------------------------------------- lifecycle

    @property
    def staged(self) -> int:
        """Number of slots currently staged (ready, transfer enqueued)."""
        with self._cv:
            return len(self._ring)

    def close(self) -> None:
        """Stop the worker thread and drop staged slots (idempotent)."""
        with self._cv:
            if self._closed:
                return
            self._closed = True
            self._ring.clear()
            self._cv.notify_all()
        self._thread.join(timeout=10.0)

    def __enter__(self) -> "SeedStager":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def make_stager(staging, stream, *, depth: int, spec, executor, pipeline):
    """Resolve a driver's ``staging`` argument into ``(stager, owned)``.

    ``staging`` may be ``None`` (defer to ``spec.prefetch.staging``), a
    bool, or an already-built ``SeedStager`` (advanced callers sharing a
    stager across drivers — adopted, not owned, so the driver's
    ``close()`` leaves it running).  When a stager is built here
    (``owned=True``), the executor's ``seed_sharding(pipeline)`` hook
    (when present) chooses where the staged seeds land — e.g. the
    shard_map executor pre-shards them along the worker axis so the
    jitted program never reshards.
    """
    if staging is None:
        staging = spec.prefetch.staging
    if isinstance(staging, SeedStager):
        return staging, False
    if not staging:
        return None, False
    sharding = None
    hook = getattr(executor, "seed_sharding", None)
    if hook is not None:
        sharding = hook(pipeline)
    return SeedStager(stream, depth=depth, lead=spec.prefetch.lead,
                      sharding=sharding), True
