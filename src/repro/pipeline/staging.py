"""Host-side asynchronous seed/feature staging.

PR 2's double-buffered prefetch overlapped the *device* half of minibatch
preparation (sampling + feature ``all_to_all``) with model compute, but
every step still blocked on host work: ``SeedStream.seeds(k)`` runs the
hash-rank argsort over all labeled nodes on the host, and its result is
synchronously transferred to the device before the prepare can even be
dispatched.  SALIENT ("Accelerating Training and Inference of GNNs with
Fast Sampling and Pipelining", arXiv 2110.08450) shows this host-side
batch-preparation pipeline is worth a large factor on top of device-side
overlap — the host must ride *ahead* of the device, not in lockstep.

``SeedStager`` is that host-side pipeline stage: a background worker
thread computes ``SeedStream.seeds(k)`` / ``salt(k)`` for future step
indices off the critical path and eagerly starts their H2D transfers via
``jax.device_put``, keeping a bounded ring of ``depth + lead`` staged
slots warm.  Drivers then consume already-resident device arrays:

  * ``depth``  — how many prepared batches the prefetch driver keeps in
                 flight (``PrefetchSpec.depth``); the stager must cover
                 them so a refill never blocks on the host.
  * ``lead``   — extra slots staged beyond the driver's own lookahead
                 (``PrefetchSpec.lead``); this is the actual host-side
                 overlap margin.

Determinism: the stager changes *when* seeds are computed, never *what*
they are — every slot is ``(stream.seeds(k), stream.salt(k))`` for a
concrete step index ``k``, and ``SeedStream`` is a pure function of
``k``.  Staged execution is therefore bit-identical to unstaged execution
for any placement scheme, executor, and prefetch depth
(``tests/test_staging.py`` asserts it).

Consumption is index-checked: ``get(k)`` serves the ring head only when
the head *is* step ``k``; any out-of-sequence request (a driver restart,
an explicit ``step_idx`` jump) drains the ring and refills it from ``k``
— exactly mirroring the prefetch drivers' queue-refill semantics, so
restarts replay the continuous run bit-for-bit.
"""
from __future__ import annotations

import collections
import threading

import jax
import numpy as np

from repro.obs import trace as _trace


class SeedStager:
    """Background staging of per-step seeds/salt with eager H2D transfer.

    Parameters
    ----------
    stream : repro.pipeline.prefetch.SeedStream
        The deterministic seed stream; the stager calls its pure host
        half (``seeds_host`` / ``salt_int``) off-thread — no JAX tracing
        state is touched on the worker thread beyond ``device_put``.
    depth : int, default 0
        The consuming driver's prefetch depth (``0`` for the sync
        driver).  Sizes the ring so queue refills are fully covered.
    lead : int, default 1
        Extra staged slots beyond ``depth`` — how far the host runs ahead
        of the device.  Must be >= 1 (a zero-slot ring stages nothing).
    sharding : jax.sharding.Sharding, optional
        Placement for the staged ``(P, batch)`` seed arrays (e.g. the
        shard_map executor's worker-axis ``NamedSharding``).  ``None``
        commits to the default device.

    Examples
    --------
    >>> stager = SeedStager(stream, depth=1, lead=2)     # doctest: +SKIP
    >>> seeds, salt = stager.get(0)                      # doctest: +SKIP
    >>> stager.close()                                   # doctest: +SKIP
    """

    def __init__(self, stream, *, depth: int = 0, lead: int = 1,
                 sharding=None):
        if depth < 0:
            raise ValueError(f"depth must be >= 0, got {depth}")
        if lead < 1:
            raise ValueError(
                f"staging lead must be >= 1 (got {lead}); lead 0 would "
                f"stage nothing ahead of the driver's own lookahead")
        self.stream = stream
        self.slots = int(depth) + int(lead)
        self.sharding = sharding
        self._cv = threading.Condition()
        self._ring: collections.deque = collections.deque()
        self._want: int | None = None     # next index the worker produces
        self._gen = 0                     # bumped on drain/refill (seek)
        self._error: BaseException | None = None
        self._closed = False
        self._thread = threading.Thread(target=self._worker, daemon=True,
                                        name="repro-seed-stager")
        self._thread.start()

    # ------------------------------------------------------------ producer

    def _produce(self, k: int):
        """Compute step ``k``'s seeds/salt on the host and start their
        device transfer.  Runs on the worker thread; the host half is
        pure numpy (``SeedStream.seeds_host``), then ``jax.device_put``
        enqueues the (async where supported) H2D copy.

        Under the multi-process executor the sharding spans devices this
        process cannot address; ``jax.make_array_from_callback`` then
        assembles the global array from this rank's addressable rows
        (every rank computes the identical full ``(P, batch)`` host
        table, so the rows are consistent by construction).

        Spans recorded here land on this worker thread's own trace
        track (the tracer's span stacks are thread-local)."""
        with _trace.span("stager/produce", cat="stager", step=k):
            with _trace.span("stager/seeds_host", cat="stager"):
                seeds_np = self.stream.seeds_host(k)
            salt_np = np.uint32(self.stream.salt_int(k))
            with _trace.span("stager/h2d", cat="stager"):
                seeds = self._put(seeds_np)
                salt = jax.device_put(salt_np)
        return seeds, salt

    def _put(self, host_array):
        """Start ``host_array``'s transfer to ``self.sharding`` (or the
        default device); handles non-fully-addressable shardings via the
        callback assembly path (see ``_produce``).  Works for any array
        whose leading axis is the worker axis."""
        if self.sharding is not None \
                and not self.sharding.is_fully_addressable:
            return jax.make_array_from_callback(
                host_array.shape, self.sharding,
                lambda idx: host_array[idx])
        return jax.device_put(host_array, self.sharding)

    def _worker(self) -> None:
        while True:
            with self._cv:
                while not self._closed and (
                        self._want is None
                        or len(self._ring) >= self.slots
                        or self._error is not None):
                    self._cv.wait()
                if self._closed:
                    return
                gen, k = self._gen, self._want
            try:
                item = self._produce(k)
            except BaseException as e:  # surfaced by the next get()
                with self._cv:
                    if self._gen == gen:
                        self._error = e
                        self._cv.notify_all()
                continue
            with self._cv:
                if self._gen != gen or self._closed:
                    continue            # stale: a seek raced the produce
                self._ring.append((k, item))
                self._want = k + 1
                self._cv.notify_all()

    # ------------------------------------------------------------ consumer

    def _seek_locked(self, k: int) -> None:
        self._gen += 1
        self._ring.clear()
        self._error = None
        self._want = int(k)
        self._cv.notify_all()

    def seek(self, k: int) -> None:
        """Drain the ring and restart staging from step ``k`` (also what
        an out-of-sequence ``get`` does implicitly)."""
        with self._cv:
            self._seek_locked(k)

    def get(self, k: int):
        """Staged ``(seeds, salt)`` device arrays for step ``k``.

        Serves the ring head when it is step ``k``; otherwise drains and
        refills from ``k`` (restart semantics).  Blocks until the slot is
        staged; re-raises any error the worker thread hit.  The
        ``stager/get`` span covers any such wait — a long one in a trace
        means the ring is not riding far enough ahead (raise
        ``PrefetchSpec.lead``).
        """
        k = int(k)
        with _trace.span("stager/get", cat="stager", step=k), self._cv:
            if self._closed:
                raise RuntimeError("SeedStager is closed")
            head = self._ring[0][0] if self._ring else self._want
            if head != k:
                self._seek_locked(k)
            while not self._ring:
                if self._error is not None:
                    err, self._error = self._error, None
                    self._cv.notify_all()   # let the worker retry
                    raise err
                if self._closed:
                    raise RuntimeError("SeedStager is closed")
                self._cv.wait()
            _, item = self._ring.popleft()
            self._cv.notify_all()           # a slot freed: keep staging
            return item

    # ----------------------------------------------------------- lifecycle

    @property
    def staged(self) -> int:
        """Number of slots currently staged (ready, transfer enqueued)."""
        with self._cv:
            return len(self._ring)

    def close(self) -> None:
        """Stop the worker thread and drop staged slots (idempotent)."""
        with self._cv:
            if self._closed:
                return
            self._closed = True
            self._ring.clear()
            self._cv.notify_all()
        self._thread.join(timeout=10.0)

    def __enter__(self) -> "SeedStager":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def _aligned_zeros(shape, dtype, align: int = 64) -> np.ndarray:
    """Zeroed array whose data pointer is ``align``-byte aligned.

    XLA:CPU only adopts an external (dlpack) buffer zero-copy when it
    meets its 64-byte alignment requirement; numpy's allocator makes no
    such promise, and a misaligned staged-row buffer would silently fall
    back to a full copy at first use — costing more than the gather it
    feeds."""
    size = int(np.prod(shape)) * np.dtype(dtype).itemsize
    raw = np.zeros(size + align, np.uint8)
    off = (-raw.ctypes.data) % align
    return raw[off:off + size].view(dtype).reshape(shape)


_U32 = 0xFFFFFFFF
_SENTINEL32 = np.iinfo(np.int32).max


def _np_hash_u32(x: np.ndarray, salt: int) -> np.ndarray:
    """Numpy transcription of ``repro.core.sampler.hash_u32`` —
    SplitMix32-style, bit-identical (uint32 wraparound semantics)."""
    x = x.astype(np.uint32) + np.uint32((salt * 0x9E3779B9) & _U32)
    x = (x ^ (x >> np.uint32(16))) * np.uint32(0x85EBCA6B)
    x = (x ^ (x >> np.uint32(13))) * np.uint32(0xC2B2AE35)
    return x ^ (x >> np.uint32(16))


def _frontier_src_nodes_host(indptr: np.ndarray, indices: np.ndarray,
                             seeds: np.ndarray, fanouts, salt: int
                             ) -> np.ndarray:
    """One worker's final-level frontier, replayed in pure numpy.

    Transcribes ``sample_neighbors`` + the ``src_nodes`` half of
    ``relabel`` (``repro.core.sampler``) level by level: same SplitMix
    draws, same sort-based unique, same -1 padding — the returned array
    is bit-identical to ``sample_mfgs(...)[-1].src_nodes``
    (``tests/test_staging.py`` asserts it).  Pure numpy so the staging
    thread never enqueues device work.
    """
    cur = np.asarray(seeds, np.int32)
    for depth, fanout in enumerate(fanouts):
        lsalt = (int(salt) * 1000003 + depth) & _U32
        seed_ok = cur >= 0
        v = np.where(seed_ok, cur, 0)
        start = indptr[v].astype(np.int64)
        deg = indptr[v + 1].astype(np.int64) - start
        cols = np.arange(fanout, dtype=np.int64)[None, :]
        bits = _np_hash_u32(
            v[:, None].astype(np.uint32) * np.uint32(2654435761)
            + np.arange(fanout, dtype=np.uint32)[None, :], lsalt)
        rand_idx = (bits % np.maximum(deg, 1)[:, None].astype(np.uint32)
                    ).astype(np.int64)
        col = np.where((deg <= fanout)[:, None], cols, rand_idx)
        valid = (cols < np.minimum(deg, fanout)[:, None]) \
            & seed_ok[:, None]
        # out-of-window reads only happen on masked slots; clamp like
        # XLA's gather does so they stay in bounds
        idx = np.clip(start[:, None] + col, 0, indices.shape[0] - 1)
        samples = np.where(valid, indices[idx], -1).astype(np.int32)

        S = cur.shape[0]
        flat = samples.ravel()
        fv = valid.ravel()
        seeds_sorted = np.sort(np.where(seed_ok, cur, _SENTINEL32))
        pos = np.clip(np.searchsorted(seeds_sorted, flat), 0, S - 1)
        is_seed = (seeds_sorted[pos] == flat) & fv
        ns_sorted = np.sort(np.where(fv & ~is_seed, flat, _SENTINEL32))
        is_new = np.concatenate(
            [np.ones(1, bool), ns_sorted[1:] != ns_sorted[:-1]])
        is_new &= ns_sorted != _SENTINEL32
        new_nodes = np.full(flat.shape[0], -1, np.int32)
        n_new = int(is_new.sum())
        new_nodes[:n_new] = ns_sorted[is_new]
        cur = np.concatenate([np.where(seed_ok, cur, -1), new_nodes])
    return cur


class FeatureStager(SeedStager):
    """A ``SeedStager`` that additionally stages the step's feature rows.

    The ``staged`` feature store (``repro.core.feature_store``) removes
    the feature ``all_to_all`` from the traced program entirely; the rows
    have to come from somewhere, and this is it.  For each staged step
    ``k`` the worker thread:

      1. computes ``(seeds, salt)`` exactly like ``SeedStager``;
      2. **replays the sampler on the host** — a pure-numpy transcription
         of ``sample_mfgs`` on the full relabeled topology with the same
         ``(seeds, salt)`` (``_frontier_src_nodes_host``).  The sampler
         is a pure function of ``(seeds, salt)`` (stateless SplitMix
         hashing, paper §4.2) and every placement scheme draws the
         *bit-identical* minibatch, so one hybrid-style replay yields the
         exact frontier the device program will sample, for any scheme.
         Numpy (not a jitted replay) on purpose: on single-device
         backends a producer-thread device program would serialize behind
         the in-flight training step and stall the ring;
      3. gathers the frontier's feature rows from the host copy of the
         full ``(P, n_max, D)`` table with one fancy index (rows of
         ``-1``-padded slots are zeroed, matching ``fetch_features``'s
         masking — the value equality, not just numerical closeness, is
         asserted in ``tests/test_feature_store.py``);
      4. zeroes slots the pinned cache will serve (cold-only staging —
         the store's ``jnp.where`` picks cache rows at hit positions, so
         the zeroes are never read as data).  Only when the store's
         ``hot_rows_from_cache`` says hits really come from the device
         cache; a host-combine ``StagedStore`` stages hot rows too;
      5. starts the H2D transfer of ``(seeds, salt, rows)``.

    The (P, S, D) row buffers come from a small recycled pool rather
    than a fresh allocation per step: at wide D a fresh buffer is
    hundreds of MB whose page-fault + unmap traffic costs tens of ms per
    step — more than the gather itself.  Reuse makes the write pattern
    incremental (gather live slots, zero only slots that were live last
    cycle), so the bytes touched track the live frontier, not the padded
    capacity.  Because ``_put_rows`` hands the buffer to the device
    zero-copy (dlpack), recycling is only sound once the pooled buffer's
    previous reader is done; see ``recycles_buffers`` for the fence
    contract and ``_stage_rows`` for the pool-distance argument.

    ``get(k)`` therefore returns a 3-tuple.  How the rows reach the
    store's ``fetch`` is executor-specific: the shard_map runner threads
    them through ``prepare(seeds, salt, staged_rows)`` (they land in the
    fused donated-FIFO program directly), while the vmap runner attaches
    them to the prepared batch *outside* the traced prepare half —
    passing a (P, N, D) array through prepare would copy it once more at
    the prepare -> consume jit boundary.

    Requires a full feature layout (``local_parts=None``) — a rank-local
    build never materializes remote rows, so the host gather cannot run.
    """

    #: Staged row buffers are recycled (see class docstring).  A driver
    #: consuming this stager must not let a step's device reads stay
    #: in flight for more than one step after ``step`` returns — the
    #: prefetch drivers guarantee it by materializing each step's loss
    #: before returning when this flag is set.
    recycles_buffers = True

    def __init__(self, stream, *, pipeline, depth: int = 0, lead: int = 1,
                 sharding=None):
        layout = pipeline.layout
        if getattr(layout, "local_parts", None) is not None:
            raise ValueError(
                "the staged feature store needs the full feature layout: "
                "a rank-local build (local_parts) never materializes "
                "remote partitions' rows, so the host-side gather cannot "
                "serve the frontier.  Build with local_parts=None.")
        graph = pipeline.graph_replicated
        if graph is None:
            graph = layout.graph
        self._fanouts = tuple(int(f) for f in pipeline.spec.sampler.fanouts)
        # pure-numpy replay state: the producer thread must never enqueue
        # device programs of its own (on single-device backends they would
        # serialize behind the training step it is trying to run ahead of)
        self._indptr_np = np.asarray(graph.indptr)
        self._indices_np = np.asarray(graph.indices)
        self._offsets_np = np.asarray(layout.offsets)
        self._feats_np = np.asarray(layout.features)
        cache = pipeline.cache
        # cold-only staging (zero the slots the pinned cache will
        # serve) only when the store actually serves hits from the
        # cache; a host-combine StagedStore wants the full rows staged
        store = getattr(pipeline, "feature_store", None)
        skip_hits = (cache is not None
                     and getattr(store, "hot_rows_from_cache", True))
        self._cache_ids_np = np.asarray(cache.ids) if skip_hits else None
        # recycled row-buffer pool (see _stage_rows for sizing): buffers
        # and their previous cycle's live mask, allocated lazily at the
        # first produce (the frontier capacity is only known then)
        self._pool_n = 2 * int(depth) + int(lead) + 1
        self._pool: list | None = None
        self._pool_valid: list | None = None
        self._last_k: int | None = None
        super().__init__(stream, depth=depth, lead=lead, sharding=sharding)

    def _stage_rows(self, k: int, frontier: np.ndarray) -> np.ndarray:
        """Gather the (P, S) frontier's rows into a pooled (P, S, D)
        buffer, writing only what changed.

        Live slots (valid ids the pinned cache will not serve) get their
        row; slots live last cycle but not now are re-zeroed; everything
        else is untouched — so the bytes written scale with the live
        fraction, not the padded frontier capacity.

        Pool sizing: buffer for step ``k`` is rewritten at step
        ``k + pool_n``, whose produce starts only after the driver popped
        item ``k + pool_n - (depth + lead)`` from the ring, i.e. during
        driver step ``k + pool_n - 2*depth - lead``.  The vmap runner
        dispatches step ``k``'s consume (the buffer's last reader) during
        driver step ``k``, and a driver consuming a recycling stager
        materializes each step's loss before returning (the
        ``recycles_buffers`` contract) — so ``pool_n = 2*depth + lead +
        1`` puts at least one fully-synced driver step between the last
        read and the rewrite.  Any discontinuity (seek/restart) drops the
        pool instead of reasoning about in-flight readers; the dlpack
        handles keep the orphaned buffers alive until the device is done
        with them.
        """
        valid = frontier >= 0
        ids = self._cache_ids_np
        if ids is not None:
            K = ids.shape[1]
            for p in range(ids.shape[0]):
                pos = np.clip(np.searchsorted(ids[p], frontier[p]),
                              0, K - 1)
                valid[p] &= ~((ids[p][pos] == frontier[p]) & valid[p])
        shape = frontier.shape + (self._feats_np.shape[2],)
        if (self._pool is None or self._last_k is None
                or k != self._last_k + 1 or self._pool[0].shape != shape):
            self._pool = [_aligned_zeros(shape, self._feats_np.dtype)
                          for _ in range(self._pool_n)]
            self._pool_valid = [None] * self._pool_n
        self._last_k = k
        slot = k % self._pool_n
        rows, prev = self._pool[slot], self._pool_valid[slot]
        if prev is not None:
            rows[prev & ~valid] = 0.0
        src = frontier[valid]
        own = np.searchsorted(self._offsets_np, src, side="right") - 1
        rows[valid] = self._feats_np[own, src - self._offsets_np[own]]
        self._pool_valid[slot] = valid
        return rows

    def _produce(self, k: int):
        with _trace.span("stager/produce", cat="stager", step=k):
            with _trace.span("stager/seeds_host", cat="stager"):
                seeds_np = self.stream.seeds_host(k)
            salt_int = self.stream.salt_int(k)
            with _trace.span("stager/frontier_replay", cat="stager"):
                frontier = np.stack([
                    _frontier_src_nodes_host(
                        self._indptr_np, self._indices_np, seeds_np[p],
                        self._fanouts, salt_int)
                    for p in range(seeds_np.shape[0])])
            with _trace.span("stager/gather_rows", cat="stager"):
                rows_np = self._stage_rows(k, frontier)
            with _trace.span("stager/h2d", cat="stager"):
                seeds = self._put(seeds_np)
                rows = self._put_rows(rows_np)
                salt = jax.device_put(np.uint32(salt_int))
        return seeds, salt, rows

    def _put_rows(self, rows_np: np.ndarray):
        """Transfer the staged rows, zero-copy where the backend allows.

        On a single-device (CPU) backend a dlpack import hands the
        pooled buffer over without copying (~half the staging cost at
        wide D); the pool distance plus the driver's per-step sync (the
        ``recycles_buffers`` contract) guarantee the aliased buffer is
        not rewritten while the device still reads it.  Sharded /
        multi-host placements fall back
        to the ``_put`` transfer paths."""
        if self.sharding is None:
            try:
                return jax.dlpack.from_dlpack(rows_np)
            except Exception:       # non-importable layout: copy instead
                pass
        return self._put(rows_np)


def make_stager(staging, stream, *, depth: int, spec, executor, pipeline):
    """Resolve a driver's ``staging`` argument into ``(stager, owned)``.

    ``staging`` may be ``None`` (defer to ``spec.prefetch.staging``), a
    bool, or an already-built ``SeedStager`` (advanced callers sharing a
    stager across drivers — adopted, not owned, so the driver's
    ``close()`` leaves it running).  When a stager is built here
    (``owned=True``), the executor's ``seed_sharding(pipeline)`` hook
    (when present) chooses where the staged seeds land — e.g. the
    shard_map executor pre-shards them along the worker axis so the
    jitted program never reshards.

    Pipelines whose feature store stages rows externally
    (``store.external_rows``, i.e. the ``staged`` store) *require* a
    ``FeatureStager`` — the traced program performs no feature exchange,
    so the rows must come from the ring.  For them a ``FeatureStager`` is
    built even when ``staging`` is falsy, and an adopted plain
    ``SeedStager`` is rejected.
    """
    store = getattr(pipeline, "feature_store", None) \
        if pipeline is not None else None
    wants_rows = bool(getattr(store, "external_rows", False))
    if staging is None:
        staging = spec.prefetch.staging
    if isinstance(staging, SeedStager):
        if wants_rows and not isinstance(staging, FeatureStager):
            raise ValueError(
                "the staged feature store needs a FeatureStager (its "
                "slots carry the step's feature rows); got a seed-only "
                "SeedStager")
        return staging, False
    if not staging and not wants_rows:
        return None, False
    sharding = None
    hook = getattr(executor, "seed_sharding", None)
    if hook is not None:
        sharding = hook(pipeline)
    if wants_rows:
        return FeatureStager(stream, pipeline=pipeline, depth=depth,
                             lead=spec.prefetch.lead,
                             sharding=sharding), True
    return SeedStager(stream, depth=depth, lead=spec.prefetch.lead,
                      sharding=sharding), True
