"""``repro.pipeline`` — the composable API for distributed sampling-based
GNN training.

Pipeline API
============

The paper's claim (FastSample, arXiv 2311.17847) is that the partitioning
scheme and the sampling kernel are *synergistic* yet independent choices.
This package makes that the shape of the code: five orthogonal components,
each swappable without touching the others.

  ``PlanSpec``      where data lives: a *placement-scheme registry name*
                    (``repro.core.placement``) — "vanilla" (topology +
                    features partitioned), "hybrid" (topology replicated,
                    features partitioned), "hybrid_partial" (top-``frac``
                    highest-degree in-edge lists replicated, vanilla
                    exchange fallback for the cold rest), or any entry
                    third parties add with ``register_scheme`` — plus a
                    *partitioner registry name* (``repro.core.partition``:
                    "ldg", "labelprop", "metis", "random") deciding node
                    placement, an optional hot-remote feature cache
                    (``cache_capacity`` built by the ``cache_policy``
                    registry entry: "degree" or "frequency"), and
                    partitioner balance slacks.
  ``SamplerSpec``   how a level is sampled: fanouts + a *level-backend
                    name* resolved through the registry in
                    ``repro.core.sampler`` ("reference", "unfused",
                    "fused_pallas", or anything third parties register
                    with ``register_backend``).
  executor          how the per-worker program runs, resolved through the
                    registry in ``repro.pipeline.executor``: "vmap"
                    (single-device simulation, bit-identical collective
                    semantics) or "shard_map" (device mesh).  Executors
                    also implement the double-buffered prefetch binding.
  ``PrefetchSpec``  how far minibatch *preparation* (sampling +
                    pack_by_owner + feature all_to_all / cache lookup)
                    runs ahead of model compute.  ``depth=0`` is the
                    synchronous path (driver registry name "sync");
                    ``depth>=1`` double-buffers ("double_buffer") —
                    bit-identical results either way, see
                    ``repro.pipeline.prefetch``.
  ``DataSpec``      what graph to train on: a *graph-source registry
                    name* (``repro.data``: "uniform", "powerlaw(alpha)",
                    "rmat(a,b,c,d)", "sbm(k,p_in,p_out)") or a path to a
                    saved dataset, plus synthetic generation knobs —
                    consumed by ``Pipeline.build_from_source``.
  ``Pipeline``      the factory tying them together:
                    partition -> layout -> plan -> shards -> caches in
                    one ``build`` call (``build_from_source`` prepends
                    dataset resolution).

Example — the paper's hybrid+fused scenario with a 4096-entry cache and
depth-1 prefetch::

    from repro.pipeline import (Pipeline, PipelineSpec, PlanSpec,
                                PrefetchSpec, SamplerSpec)

    spec = PipelineSpec(
        plan=PlanSpec(num_parts=8, scheme="hybrid", cache_capacity=4096),
        sampler=SamplerSpec(fanouts=(15, 10, 5), backend="fused_pallas"),
        executor="vmap", prefetch=PrefetchSpec(depth=1))
    pipe = Pipeline.build(graph, features, labels, spec)

    driver = pipe.train_driver(loss_fn, lr=6e-3, batch=1024)
    for k in range(steps):
        params, opt_state, loss, metrics = driver.step(params, opt_state)
    # pipe.counter.rounds  -> communication rounds traced per step
    # metrics["cache_hit_rate"] -> fraction of features served locally

``Pipeline.train_step`` remains the raw synchronous per-step function for
callers that manage their own seeds.  Legacy scheme strings parse via
``PipelineSpec.from_scheme("hybrid+fused", num_parts=8,
fanouts=(15, 10, 5))``.  Scheme ablations can share one partitioning
through ``Pipeline.from_layout(layout, spec)``.

Migration from the seed API
---------------------------

``repro.core.dist.make_worker_step`` and
``repro.core.cache.build_degree_caches`` still work but emit
``DeprecationWarning`` — placement, kernel, cache, and executor choices
all route through this package now, and new schemes land as
``register_scheme`` registry entries instead of new forks.  Code that
imported the ``VanillaPlan`` / ``HybridPlan`` dataclasses from
``repro.core.partition`` directly should migrate to
``repro.core.placement.resolve_scheme(name).build(layout)`` (the old
dataclasses remain as thin legacy containers).
"""
from repro.core.cache import (HotSetScorer, available_cache_policies,
                              available_hot_scorers, register_cache_policy,
                              register_hot_scorer, resolve_cache_policy,
                              resolve_hot_scorer)
from repro.core.partition import (Partitioner, available_partitioners,
                                  register_partitioner,
                                  resolve_partitioner)
from repro.core.feature_store import (FeatureStore,
                                      available_feature_stores,
                                      register_feature_store,
                                      resolve_feature_store)
from repro.data.sources import (available_sources, register_source,
                                resolve_source)
from repro.data.spec import DataSpec, resolve_dataset
from repro.core.placement import (PlacementPlan, PlacementScheme,
                                  available_schemes, register_scheme,
                                  resolve_scheme)
from repro.pipeline.executor import (ShardMapExecutor, VmapExecutor,
                                     available_executors, register_executor,
                                     resolve_executor)
from repro.pipeline.pipeline import Pipeline
from repro.pipeline.prefetch import (DoubleBufferDriver, PreparedBatch,
                                     SeedStream, SyncDriver,
                                     available_prefetchers,
                                     register_prefetcher,
                                     resolve_prefetcher)
from repro.pipeline.specs import (PipelineSpec, PlanSpec, PrefetchSpec,
                                  SamplerSpec)
from repro.pipeline.staging import FeatureStager, SeedStager

__all__ = [
    "Pipeline", "PipelineSpec", "PlanSpec", "SamplerSpec", "PrefetchSpec",
    "DataSpec", "resolve_dataset",
    "register_source", "resolve_source", "available_sources",
    "VmapExecutor", "ShardMapExecutor",
    "register_executor", "resolve_executor", "available_executors",
    "PlacementScheme", "PlacementPlan",
    "register_scheme", "resolve_scheme", "available_schemes",
    "Partitioner", "register_partitioner", "resolve_partitioner",
    "available_partitioners",
    "register_cache_policy", "resolve_cache_policy",
    "available_cache_policies",
    "HotSetScorer", "register_hot_scorer", "resolve_hot_scorer",
    "available_hot_scorers",
    "FeatureStore", "register_feature_store", "resolve_feature_store",
    "available_feature_stores",
    "PreparedBatch", "SeedStream", "SeedStager", "FeatureStager",
    "SyncDriver", "DoubleBufferDriver",
    "register_prefetcher", "resolve_prefetcher", "available_prefetchers",
]
