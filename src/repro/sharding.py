"""Logical-axis -> mesh-axis sharding rules (DESIGN.md §6).

The placement policy follows the paper's hybrid-partitioning principle:
replicate what is small (norms, biases, routers, SSM scalars), shard what is
big (embeddings, FFN, attention projections, expert banks).

Rules are divisibility-checked against the actual shapes — a dim that does
not divide its target axis falls back (expert dim -> d_model FSDP-style
sharding; head-coupled dims -> replicate) so every spec is accepted by jit.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axsize(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return math.prod(_axsize(mesh, a) for a in axis)
    return mesh.shape[axis]


def dp_axes(mesh: Mesh):
    """Data-parallel axes: ('pod','data') on the multi-pod mesh."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _fit(mesh: Mesh, dim: int, axis):
    """axis if it divides dim, else None."""
    return axis if axis is not None and dim % _axsize(mesh, axis) == 0 \
        else None


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def spec_for_param(path: str, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Sharding rule for one parameter, keyed on its tree path."""
    nd = len(shape)
    dp = dp_axes(mesh)
    dpa = dp if len(dp) > 1 else dp[0]
    leaf = path.rsplit("/", 1)[-1]

    def make(assign: dict[int, Any]) -> P:
        spec = [None] * nd
        for dim, axis in assign.items():
            d = dim % nd
            spec[d] = _fit(mesh, shape[d], axis)
        return P(*spec)

    # embeddings ------------------------------------------------------------
    if path.endswith("embed/tokens") or path.endswith("embed/head"):
        # vocab-sharded on model axis; vocab dim is the bigger one
        vdim = 0 if shape[0] > shape[-1] else nd - 1
        return make({vdim: "model"})

    # MoE expert banks (L, E, d_in, d_out) ------------------------------
    if "/moe/" in path:
        if leaf == "router":
            return P(*([None] * nd))
        # experts -> data-parallel axes (expert parallel); inner ffn dim
        # -> model.  If E doesn't divide, FSDP-shard the d_model dim on
        # 'data' instead (mixtral's E=8 case).
        e_ax = _fit(mesh, shape[1], dpa) or _fit(mesh, shape[1], "data")
        if leaf in ("w1", "w3"):
            assign = {1: e_ax, 3: "model"}
            if e_ax is None:
                assign[2] = "data"
            return make(assign)
        if leaf == "w2":
            assign = {1: e_ax, 2: "model"}
            if e_ax is None:
                assign[3] = "data"
            return make(assign)

    # attention / mlp / ssm projections --------------------------------
    if leaf in ("wq", "wk", "wv", "w1", "w3", "in_proj"):
        return make({nd - 1: "model"})
    if leaf in ("wo", "w2", "out_proj"):
        return make({nd - 2: "model"})

    # everything small: norms, biases, conv taps, SSM scalars, dt ------
    return P(*([None] * nd))


def param_specs(params, mesh: Mesh):
    """PartitionSpec tree matching ``params``."""
    return jax.tree_util.tree_map_with_path(
        lambda path, x: spec_for_param(_path_str(path), x.shape, mesh),
        params)


def param_shardings(params, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, mesh))


def batch_spec(shape: tuple[int, ...], mesh: Mesh) -> P:
    """Shard the leading (batch) dim over the data-parallel axes when it
    divides; sub-group fallbacks for small batches; replicate batch=1."""
    dp = dp_axes(mesh)
    b = shape[0]
    for cand in (dp, ("data",), ("pod",)):
        if all(a in mesh.axis_names for a in cand) \
                and b % _axsize(mesh, tuple(cand)) == 0:
            ax = cand if len(cand) > 1 else cand[0]
            return P(ax, *([None] * (len(shape) - 1)))
    return P(*([None] * len(shape)))


def cache_spec(shape: tuple[int, ...], mesh: Mesh, *, batch_dim: int = 1,
               kv_head_dim: int = 3) -> P:
    """KV cache (L, B, C, Hkv, Dh): batch over dp; kv heads over model when
    divisible, else shard the cache length over model (flash-decoding
    style partial-softmax placement), else replicate."""
    dp = dp_axes(mesh)
    dpa = dp if len(dp) > 1 else dp[0]
    spec = [None] * len(shape)
    spec[batch_dim] = _fit(mesh, shape[batch_dim], dpa) \
        or _fit(mesh, shape[batch_dim], "data")
    if _fit(mesh, shape[kv_head_dim], "model"):
        spec[kv_head_dim] = "model"
    elif len(shape) > 2 and _fit(mesh, shape[2], "model"):
        spec[2] = "model"
    return P(*spec)
