"""Deprecated alias of ``repro.launch.serve_lm`` (the LM decode demo).

The GNN serving namespace is owned by ``repro.serve`` and its launcher
``repro.launch.serve_gnn``; the unrelated LM launcher that used to live
here moved to ``repro.launch.serve_lm``.  This shim keeps
``python -m repro.launch.serve`` working with a warning.
"""
import warnings

from repro.launch.serve_lm import main, prefill_cache  # noqa: F401

warnings.warn(
    "repro.launch.serve is deprecated; the LM decode demo moved to "
    "repro.launch.serve_lm (GNN serving lives in repro.launch.serve_gnn "
    "/ repro.serve)",
    DeprecationWarning, stacklevel=2)

if __name__ == "__main__":
    main()
