"""ShapeDtypeStruct stand-ins + sharding trees for every (arch x shape).

Nothing here allocates device memory: params, optimizer state, caches and
batches are built with ``jax.eval_shape`` and sharded by the rules in
``repro.sharding``.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ModelConfig, ShapeConfig
from repro.models import lm
from repro.optim import init_opt_state
from repro.sharding import (batch_spec, cache_spec, dp_axes, param_specs)

VLM_PATCH_FRACTION = 4      # n_patches = seq_len // 4 for vlm shapes


def moment_dtype_for(cfg: ModelConfig):
    """bf16 Adam moments for >=100B-param configs (documented trade-off)."""
    return jnp.bfloat16 if cfg.param_count() > 100e9 else jnp.float32


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda k: lm.init_model(k, cfg), jax.random.key(0))


def abstract_opt_state(cfg: ModelConfig, params_struct):
    return jax.eval_shape(
        partial(init_opt_state, kind="adamw",
                moment_dtype=moment_dtype_for(cfg)), params_struct)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Model-input ShapeDtypeStructs for one input shape."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        batch = {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
        return batch
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.family == "vlm":
        n_patch = S // VLM_PATCH_FRACTION
        batch["vision_embeds"] = jax.ShapeDtypeStruct(
            (B, n_patch, cfg.d_model), jnp.bfloat16)
        batch["positions"] = jax.ShapeDtypeStruct((3, B, S), jnp.int32)
    if cfg.is_encdec:
        batch["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return batch


def abstract_decode_state(cfg: ModelConfig, shape: ShapeConfig):
    return jax.eval_shape(
        lambda: lm.init_decode_state(cfg, shape.global_batch, shape.seq_len))


# ---------------------------------------------------------------------------
# sharding trees
# ---------------------------------------------------------------------------

def batch_shardings(batch_struct: dict, mesh: Mesh):
    def one(name, s):
        if name == "positions":                       # (3, B, S)
            dp = dp_axes(mesh)
            ax = dp if len(dp) > 1 else dp[0]
            sp = P(None, ax, None) \
                if s.shape[1] % _prod(mesh, dp) == 0 else P()
            return NamedSharding(mesh, sp)
        return NamedSharding(mesh, batch_spec(s.shape, mesh))
    return {k: one(k, v) for k, v in batch_struct.items()}


def _prod(mesh, axes):
    out = 1
    for a in axes:
        out *= mesh.shape[a]
    return out


def decode_state_shardings(state_struct, mesh: Mesh):
    def rule(path, leaf):
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        shp = leaf.shape
        if len(shp) == 0:
            return NamedSharding(mesh, P())
        if "ssm" in name and len(shp) == 5:           # (L,B,H,P,N)
            return NamedSharding(mesh, cache_spec(shp, mesh, kv_head_dim=2))
        if len(shp) == 5:                              # kv caches (L,B,C,H,D)
            return NamedSharding(mesh, cache_spec(shp, mesh, kv_head_dim=3))
        if len(shp) >= 2:                              # conv buffers etc.
            sp = [None] * len(shp)
            dp = dp_axes(mesh)
            ax = dp if len(dp) > 1 else dp[0]
            if shp[1] % _prod(mesh, dp) == 0:
                sp[1] = ax
            return NamedSharding(mesh, P(*sp))
        return NamedSharding(mesh, P())
    return jax.tree_util.tree_map_with_path(rule, state_struct)


def param_shardings_tree(params_struct, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params_struct, mesh))


def opt_shardings_tree(opt_struct, params_struct, mesh: Mesh):
    pspecs = param_specs(params_struct, mesh)
    return type(opt_struct)(
        step=NamedSharding(mesh, P()),
        mu=jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs),
        nu=jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs),
    )
