"""Production mesh builders.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state, so tests/benches keep their single CPU device.
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False, model_parallel: int = 16):
    """16x16 (256 chips) per pod; (2,16,16) across 2 pods = 512 chips.

    model_parallel reshapes the per-pod 256 chips (e.g. 8 for archs whose
    head counts don't divide 16 — a §Perf beyond-paper sharding change; the
    canonical dry-run tables use the default 16x16).
    """
    dp = 256 // model_parallel
    shape = (2, dp, model_parallel) if multi_pod else (dp, model_parallel)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Whatever devices exist on this host (examples / subprocess tests)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return make_mesh((n // model_parallel, model_parallel),
                     ("data", "model"))


# TPU v5e hardware constants for the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link
