"""Online GNN inference serving launcher (``repro.serve``): train briefly,
export a ``Predictor``, replay synthetic open-loop traffic through the
queue → microbatcher → sampler → recycler path, and report latency/QPS.

  PYTHONPATH=src python -m repro.launch.serve_gnn --devices 4 \
      --requests 400 --arrival hotset --recycle
  PYTHONPATH=src python -m repro.launch.serve_gnn --devices 4 \
      --scheme "hybrid_partial(0.25)" --arrival uniform --max-delay 0.004
  PYTHONPATH=src python -m repro.launch.serve_gnn --devices 4 \
      --no-batching --rate 500        # baseline arm: one request per step

``--rate 0`` (default) calibrates the arrival rate to ~2x the measured
single-request service capacity, the regime where microbatching and
recycling actually matter.
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=4,
                    help="workers (vmap simulation)")
    ap.add_argument("--dataset", default="powerlaw(1.8)",
                    help="graph source registry name or .npz path "
                         "(see repro.data)")
    ap.add_argument("--nodes", type=int, default=5000)
    ap.add_argument("--avg-degree", type=int, default=10)
    ap.add_argument("--scheme", default="hybrid",
                    help="placement scheme registry name")
    ap.add_argument("--cache-capacity", type=int, default=0,
                    help="per-worker remote-feature cache entries")
    ap.add_argument("--train-steps", type=int, default=5,
                    help="quick training steps before exporting the "
                         "Predictor (0 = serve untrained params)")
    ap.add_argument("--requests", type=int, default=400)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="arrival rate (req/s); 0 = auto-calibrate to "
                         "~2x single-request service capacity")
    ap.add_argument("--arrival", default="hotset",
                    help="traffic pattern registry name "
                         "(uniform | hotset)")
    ap.add_argument("--hot-k", type=int, default=64,
                    help="hot-set size for hotset traffic (ranked by "
                         "--hot-scorer, shared with the cache policies)")
    ap.add_argument("--hot-scorer", default="degree",
                    help="hot-set scorer registry name ranking the "
                         "traffic/recycler hot set (repro.core.cache: "
                         "degree | frequency | blend(w))")
    ap.add_argument("--hot-prob", type=float, default=0.9,
                    help="probability a hotset arrival draws from the "
                         "hot set")
    ap.add_argument("--buckets", default="1,8,32,128",
                    help="comma-separated per-worker batch-shape buckets")
    ap.add_argument("--max-delay", type=float, default=2e-3,
                    help="microbatcher deadline (s)")
    ap.add_argument("--no-batching", action="store_true",
                    help="baseline arm: bucket (1,), zero delay — every "
                         "request served alone")
    ap.add_argument("--recycle", action="store_true",
                    help="enable the LazyGNN-style recycling cache")
    ap.add_argument("--tau", type=int, default=64,
                    help="recycler staleness bound (fresh serve steps)")
    ap.add_argument("--rho", type=float, default=1.0,
                    help="max fraction of requests served recycled")
    ap.add_argument("--recycle-capacity", type=int, default=1024)
    ap.add_argument("--salt-policy", default="fixed",
                    choices=("fixed", "step"),
                    help="'fixed' resamples the same subgraph per seed "
                         "(deterministic serving); 'step' draws fresh "
                         "samples each flush")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record a Chrome trace-event timeline "
                         "(repro.obs): real-clock serve/predict spans "
                         "plus per-request queue-wait / batch-delay / "
                         "service lanes on the virtual clock; viewable "
                         "in Perfetto")
    args = ap.parse_args()

    import time

    import jax
    import numpy as np

    from repro.obs import trace as obs_trace

    if args.trace:
        obs_trace.start(args.trace, process_name="serve_gnn")

    from repro.core.cache import resolve_hot_scorer
    from repro.data import DataSpec, dataset_stats, stats_label
    from repro.models.gnn import GNNConfig, gnn_loss, init_gnn_params
    from repro.optim import init_opt_state
    from repro.pipeline import PipelineSpec, Pipeline
    from repro.serve import GNNServer, Predictor, RecyclingCache
    from repro.serve.traffic import resolve_arrival

    fanouts = (5, 5)
    data = DataSpec(source=args.dataset, num_nodes=args.nodes,
                    avg_degree=args.avg_degree, num_features=32,
                    num_classes=16, split="random(0.3)", seed=args.seed)
    spec = PipelineSpec.from_scheme(
        args.scheme, num_parts=args.devices, fanouts=fanouts,
        cache_capacity=args.cache_capacity, data=data)
    pipe = Pipeline.build_from_source(spec=spec)
    ds = pipe.dataset
    print(f"dataset: {stats_label(dataset_stats(ds))}")

    cfg = GNNConfig(in_dim=ds.features.shape[1], hidden_dim=32,
                    num_classes=ds.num_classes, num_layers=len(fanouts),
                    fanouts=fanouts, dropout=0.0)
    params = init_gnn_params(jax.random.key(0), cfg)
    if args.train_steps:
        def loss_fn(p, mfgs, h, y, v):
            return gnn_loss(p, mfgs, h, y, v, cfg)
        with pipe.train_driver(loss_fn, batch=64, lr=0.006) as driver:
            opt = init_opt_state(params, kind="adamw")
            for k in range(args.train_steps):
                params, opt, loss, _ = driver.step(params, opt, k)
        print(f"trained {args.train_steps} steps, loss {float(loss):.4f}")

    buckets = (1,) if args.no_batching else \
        tuple(int(b) for b in args.buckets.split(","))
    max_delay = 0.0 if args.no_batching else args.max_delay
    predictor = Predictor(pipe, params, cfg, buckets=buckets,
                          base_salt=args.seed)
    predictor.warmup()

    rate = args.rate
    if rate <= 0:
        probe = np.asarray([int(i) for i in
                            resolve_hot_scorer("degree")
                            .top_ids(ds.graph, 8)])
        t0 = time.perf_counter()
        for s in probe:
            predictor.predict([int(s)])
        t1 = (time.perf_counter() - t0) / probe.size
        rate = 2.0 / t1
        print(f"calibrated: single-request service {t1*1e3:.2f} ms "
              f"-> open-loop rate {rate:.0f} req/s")

    hot_ids = resolve_hot_scorer(args.hot_scorer).top_ids(
        ds.graph, args.hot_k)
    arrivals = resolve_arrival(args.arrival)(
        args.requests, rate, ds.graph.num_nodes, seed=args.seed,
        hot_ids=hot_ids, hot_prob=args.hot_prob)

    recycler = RecyclingCache(capacity=args.recycle_capacity,
                              tau=args.tau, rho=args.rho) \
        if args.recycle else None
    server = GNNServer(predictor, buckets=buckets, max_delay=max_delay,
                       recycler=recycler, salt_policy=args.salt_policy)
    stats = server.run(arrivals, warmup=False)

    s = stats.summary()
    print(f"served {s['num_requests']} requests "
          f"({args.arrival} arrivals @ {rate:.0f} req/s, "
          f"scheme={args.scheme}, buckets={buckets}, "
          f"recycle={'on' if args.recycle else 'off'})")
    print(f"  p50 {s['p50_ms']:.3f} ms   p99 {s['p99_ms']:.3f} ms   "
          f"QPS {s['qps']:.0f}")
    print(f"  flushes {s['num_flushes']} "
          f"buckets {s['bucket_histogram']} "
          f"recycled {s['num_recycled']} "
          f"({s['recycled_fraction']:.1%})")
    if recycler is not None:
        r = s["recycler"]
        print(f"  recycler: hit-rate {r['hit_rate']:.1%} "
              f"entries {r['entries']}/{r['capacity']} "
              f"tau={r['tau']} rho={r['rho']} "
              f"expired {r['expired']} deferrals {r['rho_deferrals']}")
    if args.trace:
        tracer = obs_trace.stop()
        print(f"trace written to {args.trace} "
              f"({tracer.num_recorded} spans); view at "
              f"https://ui.perfetto.dev")


if __name__ == "__main__":
    main()
