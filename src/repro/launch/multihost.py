"""Local multi-process launcher for the ``"multiprocess"`` executor.

One JAX "host" per OS process: the launcher spawns ``num_procs`` worker
processes wired as ranks of a single ``jax.distributed`` job (coordinator
on a freshly-picked localhost port, rank/world-size/coordinator address
carried in ``REPRO_MH_*`` environment variables), captures each rank's
stdout/stderr to per-rank log files, and supervises the fleet:

  * a rank exiting non-zero kills the remaining ranks immediately and
    raises ``WorkerFailure`` carrying that rank's stderr tail — without
    this, the surviving ranks hang forever on the coordinator barrier
    (the failure mode ``tests/test_multihost.py`` provokes on purpose);
  * a wall-clock ``timeout`` bounds the whole run (hang detection).

Workers call ``init_from_env()`` before any JAX work: it selects the
gloo CPU collectives implementation (XLA's default CPU backend cannot
run cross-process collectives) and calls ``jax.distributed.initialize``
with the env-carried coordinator/rank wiring.

The same module works for any worker entrypoint — ``train_gnn.py`` uses
it to re-exec itself (``--executor multiprocess --num-procs N``), and
tests/benchmarks pass inline ``python -c`` scripts.
"""
from __future__ import annotations

import os
import socket
import subprocess
import sys
import tempfile
import time

# env vars carrying the rank wiring from launcher to workers
ENV_COORDINATOR = "REPRO_MH_COORDINATOR"     # "host:port"
ENV_NUM_PROCS = "REPRO_MH_NUM_PROCS"
ENV_RANK = "REPRO_MH_RANK"
ENV_LOCAL_DEVICES = "REPRO_MH_LOCAL_DEVICES"

_DEVICE_FLAG = "--xla_force_host_platform_device_count"


class WorkerFailure(RuntimeError):
    """A worker rank exited non-zero (or died); carries its stderr tail."""

    def __init__(self, rank: int, returncode: int, stderr_tail: str):
        self.rank = rank
        self.returncode = returncode
        self.stderr_tail = stderr_tail
        super().__init__(
            f"multihost worker rank {rank} exited with code {returncode}"
            f"; stderr tail:\n{stderr_tail}")


def pick_port() -> int:
    """A free localhost TCP port for the jax.distributed coordinator."""
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def rank_env(base_env: dict, *, rank: int, num_procs: int, port: int,
             local_devices: int = 1) -> dict:
    """The environment for worker ``rank``: ``REPRO_MH_*`` wiring plus an
    ``XLA_FLAGS`` host-device count (replacing any pre-existing
    ``--xla_force_host_platform_device_count`` so the launcher's count
    wins)."""
    env = dict(base_env)
    env[ENV_COORDINATOR] = f"127.0.0.1:{port}"
    env[ENV_NUM_PROCS] = str(num_procs)
    env[ENV_RANK] = str(rank)
    env[ENV_LOCAL_DEVICES] = str(local_devices)
    flags = [f for f in env.get("XLA_FLAGS", "").split()
             if not f.startswith(_DEVICE_FLAG)]
    flags.append(f"{_DEVICE_FLAG}={local_devices}")
    env["XLA_FLAGS"] = " ".join(flags)
    return env


def is_worker(env=None) -> bool:
    """True when this process was spawned by ``launch`` (rank env set)."""
    return ENV_RANK in (os.environ if env is None else env)


def init_from_env(env=None):
    """Initialize this worker's JAX distributed runtime from the
    launcher-provided environment.  MUST run before any JAX backend use
    (device queries, array creation, tracing).

    Returns ``(rank, num_procs)``.
    """
    env = os.environ if env is None else env
    import jax

    # XLA's default CPU collectives refuse cross-process programs
    # ("Multiprocess computations aren't implemented on the CPU
    # backend"); gloo implements them.
    jax.config.update("jax_cpu_collectives_implementation", "gloo")
    rank = int(env[ENV_RANK])
    num_procs = int(env[ENV_NUM_PROCS])
    jax.distributed.initialize(coordinator_address=env[ENV_COORDINATOR],
                               num_processes=num_procs,
                               process_id=rank)
    return rank, num_procs


def rank_trace_path(base: str, rank: int) -> str:
    """Per-rank trace file for a fleet whose merged trace is ``base``.

    Workers export to ``{base}.rank{r}``; the supervisor merges the rank
    files into ``base`` with ``merge_rank_traces`` after the fleet
    exits.
    """
    return f"{base}.rank{int(rank)}"


def merge_rank_traces(base: str, num_procs: int,
                      out: str | None = None) -> dict:
    """Merge the fleet's per-rank trace files into one Perfetto-loadable
    trace with rank-as-pid mapping.

    Reads ``rank_trace_path(base, r)`` for every rank and writes the
    merged trace to ``out`` (default: ``base`` itself).  Each rank
    becomes one process track group (``pid=r``, named ``rank{r}``);
    virtual pids inside a rank (e.g. the serving loop's request lanes)
    are shifted into rank-unique ranges.  Returns the merged dict.
    """
    from repro.obs.trace import merge_traces

    paths = [rank_trace_path(base, r) for r in range(num_procs)]
    return merge_traces(paths, out if out is not None else base)


def _stderr_tail(log_dir: str, rank: int, limit: int = 4000) -> str:
    path = os.path.join(log_dir, f"rank{rank}.err")
    try:
        with open(path, "rb") as f:
            f.seek(0, os.SEEK_END)
            size = f.tell()
            f.seek(max(0, size - limit))
            return f.read().decode("utf-8", "replace")
    except OSError:
        return f"<no stderr captured at {path}>"


def _kill_all(procs, grace: float = 5.0) -> None:
    for p in procs:
        if p.poll() is None:
            p.terminate()
    deadline = time.monotonic() + grace
    for p in procs:
        if p.poll() is None:
            try:
                p.wait(timeout=max(0.0, deadline - time.monotonic()))
            except subprocess.TimeoutExpired:
                p.kill()
                p.wait()


def launch(argv, *, num_procs: int, local_devices: int = 1,
           timeout: float = 600.0, log_dir: str | None = None,
           env: dict | None = None, poll_interval: float = 0.1) -> str:
    """Run ``argv`` as ``num_procs`` ranks of one jax.distributed job.

    Parameters
    ----------
    argv : list[str]
        Worker command line, e.g. ``[sys.executable, "-m",
        "repro.launch.train_gnn", ...]`` or ``[sys.executable, "-c",
        script]``.  Every rank runs the identical command; workers read
        their rank from the environment (``init_from_env``).
    num_procs : int
        World size (all ranks local to this machine).
    local_devices : int, default 1
        Host-placeholder devices per rank
        (``--xla_force_host_platform_device_count``); the global mesh
        spans ``num_procs * local_devices`` devices.
    timeout : float, default 600
        Wall-clock bound on the whole run; on expiry the fleet is killed
        and ``TimeoutError`` is raised (hang detection — a lost rank
        leaves the others blocked on collective barriers forever).
    log_dir : str, optional
        Directory for per-rank ``rank{r}.out`` / ``rank{r}.err`` capture
        (a fresh temp dir when omitted).  Returned on success.
    env : dict, optional
        Base environment (defaults to ``os.environ``).

    Raises
    ------
    WorkerFailure
        A rank exited non-zero; remaining ranks are killed first and the
        failing rank's stderr tail rides on the exception.
    TimeoutError
        The fleet outlived ``timeout``.
    """
    if num_procs < 1:
        raise ValueError(f"num_procs must be >= 1, got {num_procs}")
    port = pick_port()
    log_dir = log_dir or tempfile.mkdtemp(prefix="repro-multihost-")
    os.makedirs(log_dir, exist_ok=True)
    base = dict(os.environ if env is None else env)

    procs, files = [], []
    try:
        for r in range(num_procs):
            out = open(os.path.join(log_dir, f"rank{r}.out"), "wb")
            err = open(os.path.join(log_dir, f"rank{r}.err"), "wb")
            files += [out, err]
            procs.append(subprocess.Popen(
                argv, stdout=out, stderr=err,
                env=rank_env(base, rank=r, num_procs=num_procs,
                             port=port, local_devices=local_devices)))

        deadline = time.monotonic() + timeout
        while True:
            codes = [p.poll() for p in procs]
            failed = next((r for r, c in enumerate(codes)
                           if c not in (None, 0)), None)
            if failed is not None:
                _kill_all(procs)
                raise WorkerFailure(failed, codes[failed],
                                    _stderr_tail(log_dir, failed))
            if all(c == 0 for c in codes):
                return log_dir
            if time.monotonic() > deadline:
                _kill_all(procs)
                status = ", ".join(
                    f"rank{r}={'running' if c is None else c}"
                    for r, c in enumerate(codes))
                alive = next((r for r, c in enumerate(codes)
                              if c is None), 0)
                raise TimeoutError(
                    f"multihost run exceeded {timeout:.0f}s ({status}); "
                    f"rank {alive} stderr tail:\n"
                    f"{_stderr_tail(log_dir, alive)}")
            time.sleep(poll_interval)
    finally:
        _kill_all(procs)
        for f in files:
            f.close()
