"""Distributed GNN training launcher — the paper's workload through the
``repro.pipeline`` API, under vmap simulation, shard_map on real (or
host-placeholder) devices, or real OS processes (``multiprocess``).

  PYTHONPATH=src python -m repro.launch.train_gnn --devices 8 \
      --scheme hybrid+fused --epochs 3
  PYTHONPATH=src python -m repro.launch.train_gnn --devices 4 \
      --scheme hybrid --cache-capacity 4096 --shard-map --prefetch-depth 1
  PYTHONPATH=src python -m repro.launch.train_gnn --devices 4 \
      --scheme "hybrid_partial(0.25)" --cache-policy frequency
  PYTHONPATH=src python -m repro.launch.train_gnn --devices 4 \
      --dataset "rmat(0.57,0.19,0.19,0.05)" --scheme "hybrid_partial(0.1)"
  PYTHONPATH=src python -m repro.launch.train_gnn --devices 4 \
      --dataset datasets/ogbn-arxiv.npz
  PYTHONPATH=src python -m repro.launch.train_gnn --devices 4 \
      --executor multiprocess --num-procs 2 --scheme hybrid

With ``--executor multiprocess`` the parent re-execs itself as
``--num-procs`` coordinated worker processes (``repro.launch.multihost``)
and each rank materializes only its own partitions' feature arrays
(``Pipeline.build_from_source(local_parts=...)``).
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8,
                    help="workers (host placeholder devices on CPU)")
    ap.add_argument("--dataset", default="powerlaw(1.8)",
                    help="graph source: a registry name from repro.data "
                         "(uniform | powerlaw(alpha) | rmat(a,b,c,d) | "
                         "sbm(k,p_in,p_out)) or a path to a dataset saved "
                         "with repro.data.save_dataset (.npz)")
    ap.add_argument("--split", default="random(0.3)",
                    help="labeled-node split policy (random(frac) | "
                         "degree_stratified(frac)); ignored for on-disk "
                         "datasets")
    ap.add_argument("--scheme", default="hybrid+fused",
                    help="legacy string (vanilla | hybrid | hybrid+fused) "
                         "or any registered placement scheme, e.g. "
                         "'hybrid_partial(0.25)' for degree-aware partial "
                         "replication")
    ap.add_argument("--partitioner", default="ldg",
                    help="partitioner registry name "
                         "(repro.core.partition): ldg (streaming "
                         "greedy, the default) | labelprop (LDG + "
                         "label-propagation refinement, lower edge "
                         "cut) | metis (needs pymetis) | random "
                         "(locality-free baseline); parameterized "
                         "forms like 'labelprop(20)' set the sweep "
                         "count")
    ap.add_argument("--cache-capacity", type=int, default=0,
                    help="per-worker hot-remote-feature cache entries "
                         "(0 = off); composes with any scheme")
    ap.add_argument("--cache-policy", default="degree",
                    help="cache-construction policy registry name "
                         "(degree | frequency)")
    ap.add_argument("--feature-store", default="exchange",
                    help="feature-store registry name "
                         "(repro.core.feature_store): exchange (two-round "
                         "all_to_all fetch, the default) | pinned_hot "
                         "(cache's hot rows pinned in device memory, "
                         "needs --cache-capacity > 0) | staged (host "
                         "pre-gathered rows streamed ahead of the step, "
                         "needs --prefetch-depth >= 1); rows are "
                         "bit-identical across stores")
    ap.add_argument("--prefetch-depth", type=int, default=0,
                    help="double-buffered prefetch depth: overlap step "
                         "k's sampling/feature all_to_all with step k-1's "
                         "compute (0 = synchronous; results are "
                         "bit-identical at any depth)")
    ap.add_argument("--staging", action="store_true",
                    help="host-side async seed staging: compute future "
                         "steps' seed argsorts and start their H2D "
                         "transfers on a background thread "
                         "(repro.pipeline.staging; bit-identical results, "
                         "composes with any scheme/executor/depth)")
    ap.add_argument("--staging-lead", type=int, default=1,
                    help="staging ring slots beyond the prefetch depth "
                         "(how far the host runs ahead of the device)")
    ap.add_argument("--nodes", type=int, default=20000)
    ap.add_argument("--avg-degree", type=int, default=10)
    ap.add_argument("--epochs", type=int, default=3)
    ap.add_argument("--steps-per-epoch", type=int, default=10)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--lr", type=float, default=0.006)   # paper §4
    ap.add_argument("--shard-map", action="store_true",
                    help="run under shard_map on a device mesh instead of "
                         "the vmap single-device simulation "
                         "(legacy alias for --executor shard_map)")
    ap.add_argument("--executor", default=None,
                    choices=["vmap", "shard_map", "multiprocess"],
                    help="executor registry name (default: vmap, or "
                         "shard_map when --shard-map is set)")
    ap.add_argument("--num-procs", type=int, default=2,
                    help="worker processes for --executor multiprocess "
                         "(must divide --devices; each process hosts "
                         "devices/num-procs placeholder devices)")
    ap.add_argument("--mh-timeout", type=float, default=600.0,
                    help="multiprocess launcher wall-clock timeout in "
                         "seconds (hang detection)")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record a Chrome trace-event timeline of the run "
                         "(repro.obs): driver/prefetch/stager spans, "
                         "viewable in Perfetto; with --executor "
                         "multiprocess each rank writes OUT.json.rankR "
                         "and the parent merges them into OUT.json "
                         "(rank-as-pid).  Render the span summary with "
                         "'python -m repro.obs.report OUT.json --summary'")
    ap.add_argument("--trace-fence", action="store_true",
                    help="block_until_ready inside traced spans: honest "
                         "device-time attribution per span, at the cost "
                         "of destroying the prepare/consume overlap — a "
                         "profiling mode, never for production numbers")
    args = ap.parse_args()

    executor = args.executor or ("shard_map" if args.shard_map else "vmap")

    import os
    import sys

    from repro.launch import multihost

    if executor == "multiprocess" and not multihost.is_worker():
        # parent: re-exec this command line as the worker fleet, then
        # surface rank 0's captured stdout
        if args.devices % args.num_procs != 0:
            ap.error(f"--devices {args.devices} must be divisible by "
                     f"--num-procs {args.num_procs}")
        log_dir = multihost.launch(
            [sys.executable, "-m", "repro.launch.train_gnn"]
            + sys.argv[1:],
            num_procs=args.num_procs,
            local_devices=args.devices // args.num_procs,
            timeout=args.mh_timeout)
        with open(os.path.join(log_dir, "rank0.out")) as f:
            sys.stdout.write(f.read())
        if args.trace:
            multihost.merge_rank_traces(args.trace, args.num_procs)
            print(f"merged fleet trace written to {args.trace}")
        print(f"multiprocess run complete; per-rank logs in {log_dir}")
        return

    rank, local_parts = 0, None
    if executor == "multiprocess":
        # worker: join the jax.distributed job BEFORE any backend use,
        # then build only this rank's partitions' feature arrays
        rank, num_procs = multihost.init_from_env()
        per = args.devices // num_procs
        # rank-local feature builds save memory but preclude stages that
        # read remote rows: the cache copies remote hot rows, and the
        # staged store's host gather walks the full table
        if args.cache_capacity == 0 and args.feature_store != "staged":
            local_parts = (rank * per, (rank + 1) * per)
    elif executor == "shard_map":
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import jax

    from repro.obs import trace as obs_trace

    if args.trace:
        # each rank records (and exports) its own trace; the supervisor
        # merges the rank files after the fleet exits
        path = args.trace if executor != "multiprocess" \
            else multihost.rank_trace_path(args.trace, rank)
        obs_trace.start(path, fenced=args.trace_fence, pid=rank,
                        process_name=f"rank{rank}" if executor
                        == "multiprocess" else "train_gnn")

    from repro.data import DataSpec, dataset_stats, stats_label
    from repro.models.gnn import GNNConfig, gnn_loss, init_gnn_params
    from repro.optim import init_opt_state
    from repro.pipeline import Pipeline, PipelineSpec

    data = DataSpec(source=args.dataset, num_nodes=args.nodes,
                    avg_degree=args.avg_degree, num_features=100,
                    num_classes=47, split=args.split, seed=0)
    fanouts = (10, 10, 5)               # paper §4 defaults
    spec = PipelineSpec.from_scheme(
        args.scheme, num_parts=args.devices, fanouts=fanouts,
        cache_capacity=args.cache_capacity,
        cache_policy=args.cache_policy,
        partitioner=args.partitioner,
        executor=executor,
        prefetch_depth=args.prefetch_depth, staging=args.staging,
        staging_lead=args.staging_lead,
        feature_store=args.feature_store, data=data)
    pipe = Pipeline.build_from_source(spec=spec, local_parts=local_parts)
    ds = pipe.dataset
    say = print if rank == 0 else (lambda *a, **k: None)
    say(f"dataset: {stats_label(dataset_stats(ds))}")

    cfg = GNNConfig(in_dim=ds.features.shape[1], hidden_dim=256,
                    num_classes=ds.num_classes, num_layers=len(fanouts),
                    fanouts=fanouts, dropout=0.0)
    say(f"partitioned into {args.devices} by {args.partitioner!r}: "
        f"edge-cut {pipe.edge_cut_fraction:.1%}")
    if local_parts is not None:
        say(f"rank-local build: each rank materializes "
            f"{args.devices // args.num_procs} of {args.devices} "
            f"feature partitions")
    if pipe.placement is not None \
            and hasattr(pipe.placement, "replicated_edge_fraction"):
        say(f"partial replication: "
            f"{pipe.placement.replicated_edge_fraction:.1%} of edges "
            f"replicated, expected rounds/step "
            f"{pipe.expected_rounds_estimate:.2f} "
            f"(hybrid=2, vanilla={2 * cfg.num_layers})")

    def loss_fn(p, mfgs, h_src, labels, valid):
        return gnn_loss(p, mfgs, h_src, labels, valid, cfg)

    params = init_gnn_params(jax.random.key(0), cfg)
    opt_state = init_opt_state(params, kind="adamw")

    import time

    from repro.obs.metrics import get_registry

    registry = get_registry()
    # the driver context guarantees the staging thread is released even
    # when an epoch raises
    with pipe.train_driver(loss_fn, batch=args.batch, lr=args.lr,
                           optimizer="adamw", grad_clip=1.0) as driver:
        for epoch in range(args.epochs):
            t0 = time.time()
            for s in range(args.steps_per_epoch):
                params, opt_state, loss, metrics = driver.step(params,
                                                               opt_state)
                if epoch == 0 and s == 0:
                    # the round counter fills at first trace — report it
                    # only once a step has actually traced
                    say(f"scheme={args.scheme} executor={spec.executor} "
                        f"prefetch={args.prefetch_depth} "
                        f"staging={'on' if args.staging else 'off'}: "
                        f"{pipe.counter.rounds} comm rounds/step "
                        f"({pipe.counter.sampling_rounds} sampling + "
                        f"{pipe.counter.feature_rounds} feature; "
                        f"vanilla=2L={2*cfg.num_layers}, hybrid=2)")
            jax.block_until_ready(loss)
            # the epoch end already materializes metrics for the log
            # line; absorbing them here also runs the warn-once
            # sampler-overflow watch without adding a per-step sync
            registry.observe_step(
                metrics, step=(epoch + 1) * args.steps_per_epoch - 1)
            msg = (f"epoch {epoch}: loss {float(loss):.4f} "
                   f"rounds/step {pipe.counter.rounds} "
                   f"utilized-KB/step "
                   f"{float(metrics['sampling_utilized_bytes'])/1024:.0f}s+"
                   f"{float(metrics['feature_utilized_bytes'])/1024:.0f}f "
                   f"time {time.time()-t0:.2f}s")
            if args.cache_capacity:
                msg += f" cache-hit {float(metrics['cache_hit_rate']):.1%}"
            say(msg)
    if args.trace:
        tracer = obs_trace.stop()
        say(f"trace written to {args.trace} "
            f"({tracer.num_recorded} spans, {tracer.dropped} dropped); "
            f"view at https://ui.perfetto.dev or render with "
            f"python -m repro.obs.report {args.trace} --summary")


if __name__ == "__main__":
    main()
