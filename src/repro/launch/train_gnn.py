"""Distributed GNN training launcher — the paper's workload, under
shard_map on real (or host-placeholder) devices.

  PYTHONPATH=src python -m repro.launch.train_gnn --devices 8 \
      --scheme hybrid+fused --epochs 3
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8,
                    help="workers (host placeholder devices on CPU)")
    ap.add_argument("--scheme", default="hybrid+fused",
                    choices=["vanilla", "hybrid", "hybrid+fused"])
    ap.add_argument("--nodes", type=int, default=20000)
    ap.add_argument("--avg-degree", type=int, default=10)
    ap.add_argument("--epochs", type=int, default=3)
    ap.add_argument("--steps-per-epoch", type=int, default=10)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--lr", type=float, default=0.006)   # paper §4
    ap.add_argument("--shard-map", action="store_true",
                    help="run under shard_map on a device mesh instead of "
                         "the vmap single-device simulation")
    args = ap.parse_args()

    import os
    if args.shard_map:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.core import dist
    from repro.core.partition import (build_layout, build_vanilla,
                                      edge_cut, partition_graph,
                                      seeds_per_worker)
    from repro.data.synthetic_graph import make_power_law_graph
    from repro.models.gnn import GNNConfig, gnn_loss, init_gnn_params
    from repro.optim import apply_updates, init_opt_state
    from repro.optim.optimizers import clip_by_global_norm

    P_ = args.devices
    ds = make_power_law_graph(args.nodes, args.avg_degree,
                              num_features=100, num_classes=47, seed=0)
    print(f"graph: {ds.graph.num_nodes:,} nodes {ds.graph.num_edges:,} edges")
    assign = partition_graph(ds.graph, P_, ds.labeled_mask, seed=0)
    cut = edge_cut(ds.graph, assign)
    print(f"partitioned into {P_}: edge-cut {cut/ds.graph.num_edges:.1%}")
    layout = build_layout(ds.graph, ds.features, ds.labels, assign, P_)
    vplan = build_vanilla(layout)

    cfg = GNNConfig(in_dim=100, hidden_dim=256, num_classes=47,
                    num_layers=3, fanouts=(10, 10, 5), dropout=0.0)
    shards = dist.WorkerShard(features=layout.features, labels=layout.labels,
                              local_indptr=vplan.local_indptr,
                              local_indices=vplan.local_indices)

    level_fn = None
    if args.scheme == "hybrid+fused":
        from repro.kernels.ops import fused_sample_level
        level_fn = fused_sample_level
    else:
        from repro.core.sampler import sample_level_unfused
        level_fn = sample_level_unfused

    counter = dist.RoundCounter()

    def loss_fn(p, mfgs, h_src, labels, valid):
        return gnn_loss(p, mfgs, h_src, labels, valid, cfg)

    step = dist.make_worker_step(
        graph_replicated=(layout.graph if args.scheme.startswith("hybrid")
                          else None),
        offsets=layout.offsets, num_parts=P_, fanouts=cfg.fanouts,
        scheme="hybrid" if args.scheme.startswith("hybrid") else "vanilla",
        loss_fn=loss_fn, level_fn=level_fn, counter=counter)

    params = init_gnn_params(jax.random.key(0), cfg)
    opt_state = init_opt_state(params, kind="adamw")

    if args.shard_map:
        mesh = jax.make_mesh((P_,), (dist.AXIS,),
                             axis_types=(jax.sharding.AxisType.Auto,))
        smap = dist.make_shard_map_step(step, mesh, P(), P(dist.AXIS),
                                        P(dist.AXIS))

        @jax.jit
        def train_step(params, opt_state, seeds, salt):
            loss, grads = smap(params, shards, seeds, salt)
            grads, _ = clip_by_global_norm(grads, 1.0)
            params, opt_state = apply_updates(params, grads, opt_state,
                                              kind="adamw", lr=args.lr)
            return params, opt_state, loss
    else:
        @jax.jit
        def train_step(params, opt_state, seeds, salt):
            loss, grads = dist.run_stacked(step, params, shards, seeds, salt)
            grads, _ = clip_by_global_norm(grads, 1.0)
            params, opt_state = apply_updates(params, grads, opt_state,
                                              kind="adamw", lr=args.lr)
            return params, opt_state, loss

    import time
    print(f"scheme={args.scheme}: {counter.rounds or '?'} comm rounds/step "
          f"(vanilla=2L={2*cfg.num_layers}, hybrid=2)")
    for epoch in range(args.epochs):
        t0 = time.time()
        for s in range(args.steps_per_epoch):
            seeds = seeds_per_worker(layout, args.batch,
                                     epoch_salt=epoch * 1000 + s)
            params, opt_state, loss = train_step(
                params, opt_state, seeds, jnp.uint32(epoch * 1000 + s))
        print(f"epoch {epoch}: loss {float(loss):.4f} "
              f"rounds/step {counter.rounds} "
              f"time {time.time()-t0:.2f}s")


if __name__ == "__main__":
    main()
