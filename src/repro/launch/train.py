"""LM training launcher.

On the CPU container this trains REDUCED configs for real (synthetic Markov
tokens); on a TPU deployment the same entry point runs full configs on the
production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --reduced \
      --steps 50 --batch 8 --seq 128
"""
import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale variant (CPU-feasible)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--devices", type=int, default=0,
                    help="host placeholder devices for data parallelism")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    if args.devices:
        import os
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config, get_reduced
    from repro.data.tokens import MarkovTokenSource
    from repro.launch.mesh import make_host_mesh
    from repro.models import lm
    from repro.optim import init_opt_state
    from repro.sharding import param_shardings, batch_spec
    from repro.train.checkpoint import save_checkpoint
    from repro.train.loop import make_lm_train_step

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    print(f"arch={cfg.name} params={cfg.param_count():,} "
          f"family={cfg.family}")

    key = jax.random.key(0)
    params = lm.init_model(key, cfg)
    opt_state = init_opt_state(params, kind="adamw")
    step_fn = make_lm_train_step(cfg, lr=args.lr, remat=False)

    mesh = make_host_mesh()
    with mesh:
        pshard = param_shardings(params, mesh)
        params = jax.device_put(params, pshard)
        jstep = jax.jit(step_fn, donate_argnums=(0, 1))

        src = MarkovTokenSource(cfg.vocab_size, seed=0)
        t0 = time.time()
        for step in range(args.steps):
            raw = src.train_batch(args.batch, args.seq, seed=step)
            batch = {k: jnp.asarray(v) for k, v in raw.items()}
            if cfg.family == "vlm":
                npatch = args.seq // 4
                batch["vision_embeds"] = jnp.zeros(
                    (args.batch, npatch, cfg.d_model), jnp.float32)
                batch["positions"] = jnp.broadcast_to(
                    jnp.arange(args.seq), (3, args.batch, args.seq))
            if cfg.is_encdec:
                batch["frames"] = jax.random.normal(
                    jax.random.key(step),
                    (args.batch, cfg.encoder_seq, cfg.d_model), jnp.float32)
            params, opt_state, metrics = jstep(params, opt_state, batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"({(time.time()-t0)/(step+1):.2f}s/step)")

    if args.checkpoint:
        save_checkpoint(args.checkpoint,
                        {"params": params, "opt": opt_state},
                        step=args.steps)
        print("saved", args.checkpoint)


if __name__ == "__main__":
    main()
