import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Pod-scale dry-run of the PAPER'S OWN workload: distributed
sampling-based GNN training under shard_map with 256 (pod) or 512
(multipod) workers along the data axis.

Proves the hybrid/vanilla protocols lower and compile at production worker
counts (the host run in train_gnn.py uses 4-8 workers), and reports the
collective schedule of each scheme — the 2L-vs-2 round structure shows up
directly as all-to-all op counts in the compiled HLO.

The per-worker program is the unified ``repro.pipeline.worker`` step (the
same one ``Pipeline`` executes); data here is abstract ShapeDtypeStructs,
so the full ``Pipeline.build`` (which partitions a concrete graph) is
bypassed and the step is bound to the mesh directly.

  PYTHONPATH=src python -m repro.launch.dryrun_gnn --workers 256 \
      --scheme hybrid
"""
import argparse
import json

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=256,
                    choices=[256, 512])
    ap.add_argument("--scheme", default="both",
                    choices=["vanilla", "hybrid", "both"])
    ap.add_argument("--partitioner", default="ldg",
                    help="partitioner registry name recorded with the "
                         "dry-run (validated against "
                         "repro.core.partition; the abstract-shapes "
                         "trace itself is partition-independent)")
    ap.add_argument("--nodes-per-worker", type=int, default=2000)
    ap.add_argument("--batch", type=int, default=1000)   # paper's batch
    ap.add_argument("--features", type=int, default=128) # papers100M width
    ap.add_argument("--out", default="experiments/dryrun_gnn")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro import roofline
    from repro.compat import make_mesh, shard_map
    from repro.core import dist
    from repro.models.gnn import GNNConfig, gnn_loss, init_gnn_params
    from repro.pipeline import PipelineSpec
    from repro.pipeline.worker import make_worker_step

    W = args.workers
    n_max = args.nodes_per_worker
    n_total = W * n_max
    cfg = GNNConfig(in_dim=args.features, hidden_dim=256, num_classes=172,
                    num_layers=3, fanouts=(15, 10, 5), dropout=0.0)

    # abstract per-worker shards (ShapeDtypeStructs — no allocation);
    # topology stand-in: average degree 29 (papers100M-like)
    avg_deg = 29
    nnz_local = n_max * avg_deg
    sds = jax.ShapeDtypeStruct
    shards = dist.WorkerShard(
        features=sds((W, n_max, args.features), jnp.float32),
        labels=sds((W, n_max), jnp.int32),
        local_indptr=sds((W, n_max + 1), jnp.int32),
        local_indices=sds((W, nnz_local), jnp.int32),
    )
    seeds = sds((W, args.batch), jnp.int32)
    offsets = jnp.arange(W + 1, dtype=jnp.int32) * n_max

    # replicated topology for the hybrid scheme
    from repro.core.graph import CSCGraph
    graph = CSCGraph(indptr=sds((n_total + 1,), jnp.int32),
                     indices=sds((n_total * avg_deg,), jnp.int32))

    params = init_gnn_params(jax.random.key(0), cfg)

    def loss_fn(p, mfgs, h_src, labels, valid):
        return gnn_loss(p, mfgs, h_src, labels, valid, cfg)

    mesh = make_mesh((W,), (dist.AXIS,))

    schemes = ["vanilla", "hybrid"] if args.scheme == "both" \
        else [args.scheme]
    for scheme in schemes:
        spec = PipelineSpec.from_scheme(scheme, num_parts=W,
                                        fanouts=cfg.fanouts,
                                        partitioner=args.partitioner)
        counter = dist.RoundCounter()
        # hybrid needs concrete replicated topology at trace time only for
        # shapes — pass structs through a wrapper that treats it as arg
        def worker(params, shards1, seeds1, graph_indptr, graph_indices):
            g = CSCGraph(indptr=graph_indptr, indices=graph_indices)
            step = make_worker_step(
                offsets=offsets, num_parts=W, fanouts=cfg.fanouts,
                loss_fn=loss_fn, scheme=spec.plan.scheme,
                graph_replicated=g if spec.plan.scheme == "hybrid" else None,
                backend=spec.sampler.backend, counter=counter)
            return step(params, shards1, seeds1, jnp.uint32(1))

        def wrapper(params, shards_, seeds_, gi, gx):
            sq = lambda a: a[0]
            loss, grads, _metrics = worker(params, jax.tree.map(sq, shards_),
                                           seeds_[0], gi, gx)
            return loss, grads

        smap = shard_map(
            wrapper, mesh=mesh,
            in_specs=(P(), P(dist.AXIS), P(dist.AXIS), P(), P()),
            out_specs=(P(), P()),
            check=False)

        with mesh:
            lowered = jax.jit(smap).lower(params, shards, seeds,
                                          graph.indptr, graph.indices)
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        coll = roofline.collective_bytes(compiled.as_text())
        rec = {
            "workload": "gnn-distributed-train",
            "scheme": scheme, "workers": W,
            "partitioner": spec.plan.partitioner,
            "executor": "shard_map", "prefetch_depth": 0,
            "rounds_traced": counter.rounds,
            "sampling_rounds_traced": counter.sampling_rounds,
            "feature_rounds_traced": counter.feature_rounds,
            "expected_rounds": spec.expected_rounds,
            "collective_counts": coll["counts"],
            "collective_bytes_per_device": coll["total_bytes"],
            "peak_estimate_bytes": (mem.argument_size_in_bytes
                                    + mem.output_size_in_bytes
                                    + mem.temp_size_in_bytes
                                    - mem.alias_size_in_bytes),
            "status": "ok",
        }
        print(json.dumps(rec))
        os.makedirs(args.out, exist_ok=True)
        with open(os.path.join(args.out,
                               f"gnn__{scheme}__w{W}.json"), "w") as f:
            json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
