"""Batched LM serving launcher: prefill a prompt batch, then decode.

  PYTHONPATH=src python -m repro.launch.serve_lm --arch stablelm-1.6b \
      --reduced --batch 4 --prompt-len 32 --gen 16

(Formerly ``repro.launch.serve``; GNN serving is ``repro.launch.serve_gnn``.)
"""
import argparse
import time


def prefill_cache(params, tokens, cfg):
    """Run the full-sequence forward while populating the decode cache.

    Implemented as a scan of decode steps (correct for every family incl.
    ring-buffer SWA and SSM state); TPU deployments would use a fused
    prefill kernel instead.
    """
    import jax
    import jax.numpy as jnp
    from repro.models import lm

    B, S = tokens.shape
    state = lm.init_decode_state(cfg, B, max(S * 2, 64))

    def step(state, tok):
        logits, state = lm.decode_step(params, state, {"tokens": tok[:, None]},
                                       cfg)
        return state, logits[:, 0]

    state, logits = jax.lax.scan(step, state, tokens.T)
    return state, jnp.swapaxes(logits, 0, 1)      # (B, S, V)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config, get_reduced
    from repro.data.tokens import MarkovTokenSource
    from repro.models import lm

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if cfg.is_encdec:
        raise SystemExit("use a decoder-only arch for the LM server demo")
    print(f"serving {cfg.name} ({cfg.param_count():,} params)")

    params = lm.init_model(jax.random.key(0), cfg)
    src = MarkovTokenSource(cfg.vocab_size, seed=0)
    prompts = jnp.asarray(src.batch(args.batch, args.prompt_len - 1))

    t0 = time.time()
    state, logits = prefill_cache(params, prompts, cfg)
    print(f"prefill {args.batch}x{args.prompt_len}: {time.time()-t0:.2f}s")

    @jax.jit
    def decode(params, state, tok):
        logits, state = lm.decode_step(params, state, {"tokens": tok}, cfg)
        return jnp.argmax(logits[:, -1], axis=-1)[:, None], state

    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    out = [tok]
    t0 = time.time()
    for _ in range(args.gen):
        tok, state = decode(params, state, tok)
        out.append(tok)
    dt = time.time() - t0
    gen = np.asarray(jnp.concatenate(out, axis=1))
    print(f"decoded {args.gen} tokens x {args.batch} seqs in {dt:.2f}s "
          f"({args.gen*args.batch/dt:.1f} tok/s)")
    for b in range(min(args.batch, 2)):
        print(f"  seq{b}: {gen[b].tolist()}")


if __name__ == "__main__":
    main()
