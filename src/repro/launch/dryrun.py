import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

The two lines above MUST stay first: jax locks the device count on first
init, and the dry-run (and only the dry-run) needs 512 placeholder devices.

For each combo this produces:
  * the REAL module (scan-over-layers, remat): compile success proof +
    memory_analysis (bytes per device),
  * two UNROLLED depth probes (1 and 2 depth units, no remat):
    cost_analysis FLOPs/bytes + HLO-parsed collective bytes, extrapolated
    to full depth (see repro.roofline),
  * the roofline terms + dominant bottleneck.

Results are printed and appended as JSON lines to
``experiments/dryrun/<arch>__<shape>__<mesh>.json``.

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import dataclasses
import json
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (ARCH_ALIASES, ModelConfig, SHAPES, ShapeConfig,
                           get_config, get_shape)
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro import roofline
from repro.models import lm
from repro.optim import apply_updates
from repro.optim.optimizers import clip_by_global_norm


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("full-attention arch: 512k decode requires "
                       "sub-quadratic attention (DESIGN.md §5 skip)")
    return True, ""


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def build_train_step(cfg: ModelConfig, *, remat: bool, unroll: bool):
    mdt = S.moment_dtype_for(cfg)

    def train_step(params, opt_state, batch):
        def objective(p):
            loss, m = lm.lm_loss(p, batch, cfg, remat=remat, unroll=unroll)
            return loss, m
        (loss, metrics), grads = jax.value_and_grad(
            objective, has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt_state = apply_updates(params, grads, opt_state,
                                          kind="adamw", lr=1e-4,
                                          moment_dtype=mdt)
        return params, opt_state, loss
    return train_step


def build_prefill_step(cfg: ModelConfig, *, remat: bool, unroll: bool):
    def prefill_step(params, batch):
        logits, _ = lm.forward(params, batch, cfg, remat=remat,
                               unroll=unroll,
                               last_only=cfg.prefill_last_only)
        # score-only prefill output: next-token logits
        return logits[:, -1, :]
    return prefill_step


def build_serve_step(cfg: ModelConfig, *, unroll: bool):
    def serve_step(params, state, batch):
        logits, new_state = lm.decode_step(params, state, batch, cfg,
                                           unroll=unroll)
        return jnp.argmax(logits[:, -1, :], axis=-1), new_state
    return serve_step


# ---------------------------------------------------------------------------
# lower + compile one configuration
# ---------------------------------------------------------------------------

def lower_combo(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
                remat: bool = True, unroll: bool = False,
                donate: bool = True):
    """Returns (lowered, meta) for the given combo on the given mesh."""
    params_struct = S.abstract_params(cfg)
    pshard = S.param_shardings_tree(params_struct, mesh)
    batch_struct = S.input_specs(cfg, shape)
    bshard = S.batch_shardings(batch_struct, mesh)

    with mesh:
        if shape.kind == "train":
            opt_struct = S.abstract_opt_state(cfg, params_struct)
            oshard = S.opt_shardings_tree(opt_struct, params_struct, mesh)
            fn = build_train_step(cfg, remat=remat, unroll=unroll)
            jf = jax.jit(fn,
                         in_shardings=(pshard, oshard, bshard),
                         out_shardings=(pshard, oshard,
                                        NamedSharding(mesh, P())),
                         donate_argnums=(0, 1) if donate else ())
            lowered = jf.lower(params_struct, opt_struct, batch_struct)
        elif shape.kind == "prefill":
            fn = build_prefill_step(cfg, remat=remat, unroll=unroll)
            jf = jax.jit(fn, in_shardings=(pshard, bshard))
            lowered = jf.lower(params_struct, batch_struct)
        else:  # decode
            state_struct = S.abstract_decode_state(cfg, shape)
            sshard = S.decode_state_shardings(state_struct, mesh)
            fn = build_serve_step(cfg, unroll=unroll)
            jf = jax.jit(fn, in_shardings=(pshard, sshard, bshard),
                         out_shardings=(
                             NamedSharding(mesh, P()), sshard),
                         donate_argnums=(1,) if donate else ())
            lowered = jf.lower(params_struct, state_struct, batch_struct)
    return lowered


def depth_units(cfg: ModelConfig) -> tuple[int, ModelConfig, ModelConfig]:
    """(units, cfg@1unit, cfg@2units) for the cost probes."""
    if cfg.family == "hybrid" and cfg.shared_attn_every:
        every = cfg.shared_attn_every
        units = cfg.num_layers / every          # fractional remainder ok
        c1 = dataclasses.replace(cfg, num_layers=every)
        c2 = dataclasses.replace(cfg, num_layers=2 * every)
        return units, c1, c2
    if cfg.is_encdec:
        units = cfg.num_layers
        c1 = dataclasses.replace(cfg, num_layers=1, encoder_layers=1)
        c2 = dataclasses.replace(cfg, num_layers=2, encoder_layers=2)
        return units, c1, c2
    units = cfg.num_layers
    c1 = dataclasses.replace(cfg, num_layers=1)
    c2 = dataclasses.replace(cfg, num_layers=2)
    return units, c1, c2


def probe_costs(cfg: ModelConfig, shape: ShapeConfig, mesh) -> dict:
    """Compile unrolled 1- and 2-unit modules, extrapolate to full depth."""
    from repro.models import attention as attn_mod
    units, c1, c2 = depth_units(cfg)
    metrics = []
    attn_mod.PROBE_UNROLL = True          # count chunked-attention blocks
    try:
        for c in (c1, c2):
            lowered = lower_combo(c, shape, mesh, remat=False, unroll=True,
                                  donate=False)
            compiled = lowered.compile()
            ca = compiled.cost_analysis() or {}
            txt = compiled.as_text()
            coll = roofline.collective_bytes(txt)
            metrics.append({
                "flops": float(ca.get("flops", 0.0)),
                "hbm_bytes": float(ca.get("bytes accessed", 0.0)),
                "coll_bytes": float(coll["total_bytes"]),
                "fusable": float(roofline.fusable_bytes(txt)),
            })
    finally:
        attn_mod.PROBE_UNROLL = False
    return roofline.extrapolate(metrics[0], metrics[1], units)


def run_combo(arch: str, shape_name: str, mesh_name: str,
              *, skip_probes: bool = False, out_dir: str | None = None,
              param_overrides: dict | None = None) -> dict:
    cfg = get_config(arch)
    if param_overrides:
        cfg = dataclasses.replace(cfg, **param_overrides)
    shape = get_shape(shape_name)
    tp = 16
    if "_tp" in mesh_name:
        tp = int(mesh_name.split("_tp")[1])
    mesh = make_production_mesh(
        multi_pod=mesh_name.startswith("multipod"), model_parallel=tp)
    chips = mesh.devices.size

    ok, why = shape_applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "chips": chips}
    if not ok:
        rec.update(status="skipped", reason=why)
        _emit(rec, out_dir)
        return rec

    t0 = time.time()
    try:
        # 1) the REAL module: scan + remat, full depth
        lowered = lower_combo(cfg, shape, mesh, remat=(shape.kind == "train"))
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        rec["compile_s"] = round(time.time() - t0, 1)
        rec["memory"] = {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_estimate_bytes": (mem.argument_size_in_bytes
                                    + mem.output_size_in_bytes
                                    + mem.temp_size_in_bytes
                                    - mem.alias_size_in_bytes),
        }
        coll_sched = roofline.collective_bytes(compiled.as_text())
        rec["collective_schedule_counts"] = coll_sched["counts"]

        # 2) depth probes -> roofline terms
        if not skip_probes:
            costs = probe_costs(cfg, shape, mesh)
            terms = roofline.RooflineTerms(
                flops=costs["flops"], hbm_bytes=costs["hbm_bytes"],
                coll_bytes=costs["coll_bytes"],
                fusable=costs.get("fusable", 0.0),
                model_flops_global=roofline.model_flops(cfg, shape),
                chips=chips)
            rec["roofline"] = terms.as_dict()
        rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — a failure IS the result here
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 1)
    _emit(rec, out_dir)
    return rec


def _emit(rec: dict, out_dir: str | None):
    line = {k: v for k, v in rec.items() if k != "traceback"}
    print(json.dumps(line))
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
        with open(os.path.join(out_dir, name), "w") as f:
            json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="arch id (e.g. qwen2-7b); omit with --all")
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="pod",
                    help="pod | multipod | both | pod_tpN | multipod_tpN "
                         "(N-way model parallelism over the same chips)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-probes", action="store_true",
                    help="compile-only (no roofline probes)")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--opt", default="",
                    help="comma list of beyond-paper optimizations: "
                         "prefill_last,moe_shard,attn_chunk[:N]")
    args = ap.parse_args()

    overrides = {}
    for o in filter(None, args.opt.split(",")):
        if o == "prefill_last":
            overrides["prefill_last_only"] = True
        elif o == "moe_shard":
            overrides["moe_shard_constraints"] = True
        elif o.startswith("moe_group"):
            overrides["moe_num_groups"] = int(o.split(":")[1]) \
                if ":" in o else 32
        elif o.startswith("attn_chunk"):
            overrides["attn_chunk"] = int(o.split(":")[1]) \
                if ":" in o else 1024
        elif o.startswith("ce_chunk"):
            overrides["ce_seq_chunk"] = int(o.split(":")[1]) \
                if ":" in o else 512
        elif o == "ssm_shard":
            overrides["ssm_state_constraints"] = True

    archs = list(ARCH_ALIASES) if args.all else [args.arch]
    shapes = list(SHAPES) if args.shape is None else [args.shape]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mesh in meshes:
                rec = run_combo(arch, shape, mesh,
                                skip_probes=args.skip_probes,
                                out_dir=args.out,
                                param_overrides=overrides or None)
                n_fail += rec["status"] == "fail"
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
